"""Cross-cutting property tests (hypothesis) on system invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import pytest as _pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container may lack hypothesis: skip only
    # the property tests, keep the plain unit tests runnable.
    def given(*_a, **_k):
        return lambda f: _pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

from repro import models, perf
from repro.configs import get_config

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize(
    "arch", ["olmo-1b", "gemma2-27b", "mamba2-370m", "jamba-1.5-large-398b"]
)
def test_causality(arch):
    """Perturbing position j must not change any output at positions < j."""
    cfg = get_config(arch).smoke()
    params = models.init_params(cfg, KEY)
    s, j = 24, 13
    tok = jax.random.randint(KEY, (1, s), 0, cfg.vocab_size)
    tok2 = tok.at[0, j].set((tok[0, j] + 7) % cfg.vocab_size)
    l1, _ = models.forward(cfg, params, tok, remat=False)
    l2, _ = models.forward(cfg, params, tok2, remat=False)
    np.testing.assert_allclose(
        np.asarray(l1[:, :j]), np.asarray(l2[:, :j]), atol=1e-5,
        err_msg=f"{arch}: future token leaked into the past",
    )
    assert float(jnp.max(jnp.abs(l1[:, j:] - l2[:, j:]))) > 1e-6


def test_causality_chunked_impl():
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, KEY)
    tok = jax.random.randint(KEY, (1, 24), 0, cfg.vocab_size)
    tok2 = tok.at[0, 13].set((tok[0, 13] + 7) % cfg.vocab_size)
    with perf.use_perf_opts(perf.PerfOpts(impl="chunked", attn_block=8)):
        l1, _ = models.forward(cfg, params, tok, remat=False)
        l2, _ = models.forward(cfg, params, tok2, remat=False)
    np.testing.assert_allclose(
        np.asarray(l1[:, :13]), np.asarray(l2[:, :13]), atol=1e-5
    )


def test_sliding_window_forgets():
    """With window w, outputs at position p >= w+j must ignore position j."""
    cfg = dataclasses.replace(
        get_config("gemma2-27b").smoke(),
        layer_pattern=("attn_local",),
        num_layers=2,
        sliding_window=8,
    ).validate()
    params = models.init_params(cfg, KEY)
    s = 32
    tok = jax.random.randint(KEY, (1, s), 0, cfg.vocab_size)
    tok2 = tok.at[0, 2].set((tok[0, 2] + 3) % cfg.vocab_size)
    l1, _ = models.forward(cfg, params, tok, remat=False)
    l2, _ = models.forward(cfg, params, tok2, remat=False)
    # position 2 leaves every window after 2 + 8 (+1 layer of propagation is
    # impossible: the second layer's window also only sees the last 8)
    horizon = 2 + 2 * 8
    np.testing.assert_allclose(
        np.asarray(l1[:, horizon:]), np.asarray(l2[:, horizon:]), atol=1e-5
    )


@settings(max_examples=15, deadline=None)
@given(
    shift=st.integers(1, 512),
    seed=st.integers(0, 2**31 - 1),
)
def test_rope_relative_position_invariance(shift, seed):
    """RoPE'd q·k depends only on relative distance, not absolute position."""
    from repro.models.layers import apply_rope

    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 4, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 2, 32))
    p0 = jnp.arange(4)[None, :]
    p1 = p0 + shift
    s0 = jnp.einsum(
        "bqhd,bkhd->bhqk",
        apply_rope(q, p0, 10000.0),
        apply_rope(k, p0, 10000.0),
    )
    s1 = jnp.einsum(
        "bqhd,bkhd->bhqk",
        apply_rope(q, p1, 10000.0),
        apply_rope(k, p1, 10000.0),
    )
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(trips=st.integers(2, 12), seed=st.integers(0, 1000))
def test_hlo_analyzer_arbitrary_scan_depth(trips, seed):
    """Analyzer flops scale exactly with the scan trip count."""
    from repro.hlo_analysis import analyze

    def body(x, w):
        return jnp.dot(x, w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((trips, 32, 32), jnp.float32)
    a = analyze(jax.jit(scanned).lower(x, ws).compile().as_text())
    assert a["flops"] == pytest.approx(trips * 2 * 32**3, rel=0.01)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_loss_ignores_padded_labels(seed):
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, jax.random.PRNGKey(seed))
    tok = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 16), 0,
                             cfg.vocab_size)
    lab = tok.at[:, -4:].set(-1)
    l1, m1 = models.loss_fn(cfg, params, {"inputs": tok, "labels": lab})
    # changing tokens at padded positions' labels doesn't change the loss
    lab2 = lab.at[:, -4:].set(-1)
    l2, m2 = models.loss_fn(cfg, params, {"inputs": tok, "labels": lab2})
    assert float(m1["ntok"]) == 2 * 12
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_decode_position_masking():
    """Tokens beyond `pos` in the cache must not affect decode logits."""
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, KEY)
    cache = models.init_cache(cfg, 1, 16)
    # poison the tail of the cache with garbage
    poisoned = jax.tree.map(
        lambda t: t.at[..., 8:, :, :].set(99.0)
        if t.ndim == 5 else t,
        cache,
    )
    tok = jnp.zeros((1, 1), jnp.int32)
    l1, _ = models.decode_step(cfg, params, cache, tok, jnp.int32(5))
    l2, _ = models.decode_step(cfg, params, poisoned, tok, jnp.int32(5))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


# ------------------------------------------------- page-pool conservation
@given(
    seed=st.integers(0, 2**31 - 1),
    ops=st.lists(st.integers(0, 2), min_size=1, max_size=200),
)
@settings(max_examples=50, deadline=None)
def test_page_pool_refcount_algebra(seed, ops):
    """Any alloc/incref/decref interleaving keeps every page exactly free
    xor referenced — ``check()`` never trips and page counts conserve."""
    from repro.runtime.kvcache import PagePool

    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages=8, page_size=4)
    live: list[int] = []
    for op in ops:
        if op == 0:
            pid = pool.alloc()
            if pid is not None:
                live.append(pid)
        elif op == 1 and live:
            pid = live[int(rng.integers(len(live)))]
            pool.incref(pid)
            live.append(pid)
        elif op == 2 and live:
            pid = live.pop(int(rng.integers(len(live))))
            pool.decref(pid)
        pool.check()
        assert pool.pages_free + pool.pages_in_use == pool.num_pages
    for pid in live:
        pool.decref(pid)
    pool.check()
    assert pool.pages_in_use == 0


@given(
    seed=st.integers(0, 2**31 - 1),
    ops=st.lists(st.integers(0, 4), min_size=1, max_size=200),
)
@settings(max_examples=50, deadline=None)
def test_cross_pool_migration_conserves_refcounts(seed, ops):
    """§17 conservation: any interleaving of alloc/incref/decref with
    cross-pool export/import (both directions, single pages and batched
    ``migrate_pages``) keeps the *summed* refcount and page balance across
    the two pools exactly — references travel, they are never minted or
    dropped."""
    from repro.runtime.kvcache import KVCacheError, PagePool, migrate_pages

    rng = np.random.default_rng(seed)
    pools = [PagePool(num_pages=8, page_size=4),
             PagePool(num_pages=8, page_size=4)]
    live: list[tuple[int, int]] = []  # (pool_idx, pid)
    for op in ops:
        if op == 0:
            i = int(rng.integers(2))
            pid = pools[i].alloc()
            if pid is not None:
                live.append((i, pid))
        elif op == 1 and live:
            i, pid = live[int(rng.integers(len(live)))]
            pools[i].incref(pid)
            live.append((i, pid))
        elif op == 2 and live:
            i, pid = live.pop(int(rng.integers(len(live))))
            pools[i].decref(pid)
        elif op >= 3 and live:
            # migrate one live page (op 3) or a batch (op 4) to the twin
            i, pid = live[int(rng.integers(len(live)))]
            batch = [pid] if op == 3 else sorted(
                {p for j, p in live if j == i}
            )
            try:
                mapping = migrate_pages(pools[i], pools[1 - i], batch)
            except KVCacheError:
                continue  # dry destination: atomic no-op by contract
            live = [
                (1 - i, mapping[p]) if j == i and p in mapping else (j, p)
                for j, p in live
            ]
        for p in pools:
            p.check()
        # conservation across BOTH pools: every list entry is one
        # travelling reference; pages split free-xor-referenced per pool
        assert sum(p.pages_in_use for p in pools) == len(
            {(j, p) for j, p in live}
        )
        assert sum(
            pools[j].refcount(p) for j, p in {(j, p) for j, p in live}
        ) == len(live)
    for i, pid in live:
        pools[i].decref(pid)
    for p in pools:
        p.check()
        assert p.pages_in_use == 0


@_pytest.mark.parametrize("seed", [0, 1, 2])
def test_page_pool_balances_under_serving_interleavings(seed):
    """§15 containment invariant at the batcher level: a random
    interleaving of admit / cancel / budget trim / injected faults
    (poisoned emissions quarantining slots, allocation failures forcing
    evict/preempt) leaves the pool exactly consistent after every step,
    and a drained batcher holds only prefix-cache pages."""
    from repro.core import reset_entry_points
    from repro.core.faults import FaultPlan
    from repro.runtime.scheduler import Request
    from repro.runtime.serve import Engine, EngineConfig

    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, KEY)
    reset_entry_points()
    eng = Engine(cfg, params, EngineConfig(
        max_len=32, batch_quantum=2, max_batch=4, page_size=8,
        num_pages=12, prefill_chunk=8, spec_k=0,
    ))
    cb = eng.paged_continuous(slots=4)
    plan = FaultPlan.random(
        seed, sites=("step_output", "pool_alloc"), n=3, horizon=30
    )
    cb.attach_faults(plan)
    cb.pool.attach_faults(plan)
    rng = np.random.default_rng(seed)
    prompts = [tuple(int(x) for x in rng.integers(0, cfg.vocab_size, 10))
               for _ in range(3)]
    pending = [
        Request(rid=i, new_tokens=int(rng.integers(2, 10)), greedy=True,
                prompt=prompts[int(rng.integers(len(prompts)))])
        for i in range(10)
    ]
    for it in range(300):
        op = int(rng.integers(4))
        if op == 0 and pending and cb.free_slots:
            take = pending[:cb.free_slots]
            pending = list(cb.admit(take, now=float(it))) \
                + pending[len(take):]
        elif op == 1:
            seated = [r.rid for r in cb._slots if r is not None]
            if seated:
                cb.cancel(int(rng.choice(seated)), now=float(it))
        elif op == 2:
            cb.set_knobs(token_budget=int(rng.integers(5, 25)))
        cb.step(now=float(it))
        pending.extend(cb.requeued)
        cb.requeued.clear()
        pending.extend(cb.preempted)
        cb.preempted.clear()
        cb.pool.check()
        assert cb.pool.pages_free + cb.pool.pages_in_use == cb.pool.num_pages
        if not pending and not cb.has_work:
            break
    else:
        raise AssertionError("interleaving never drained")
    cb.flush(1000.0)
    cb.pool.check()
    # every slot released: the only pages still referenced belong to the
    # prefix cache, and evicting it returns the pool to empty
    cb.prefix.evict(cb.pool.num_pages)
    cb.pool.check()
    assert cb.pool.pages_in_use == 0
    eng.close()
