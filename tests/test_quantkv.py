"""Quantised int8 KV-page tests (DESIGN.md §12): quantise/dequantise
round-trip bounds, int8 kernel vs pure-jax oracle (decode + prefill), int8
chunked ingestion bitwise-equal to int8 token-by-token decode, bounded logit
drift vs the fp32 pool on a shared-prefix-style teacher-forced stream,
per-page scale COW on BlockTable.fork, scale overwrite after trim()/realloc,
and the int8 end-to-end serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core import reset_entry_points
from repro.models.attention import (
    KV_QUANT_MAX,
    dequantise_kv_rows,
    quantise_kv_rows,
)
from repro.runtime.kvcache import BlockTable, PagePool, page_bytes
from repro.runtime.scheduler import Request
from repro.runtime.serve import Engine, EngineConfig

# Measured on the smoke config: max-abs drift ~5e-3 at |logit| <= ~0.7.
# The stated acceptance bound carries ~10x margin (also gated in
# benchmarks/quantkv_bench.py -> BENCH_quantkv.json).
LOGIT_DRIFT_BOUND = 0.05


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------------- quant primitives
def test_quantise_dequantise_roundtrip_bounds():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(5, 7, 4, 16)) * 3.0, jnp.float32)
    q, scale = quantise_kv_rows(x)
    assert q.dtype == jnp.int8 and scale.shape == (5, 7)
    # symmetric full-range: per-row absmax maps to +-127
    np.testing.assert_allclose(
        np.asarray(scale),
        np.abs(np.asarray(x)).max(axis=(-2, -1)) / KV_QUANT_MAX,
        rtol=1e-6,
    )
    # round-trip error is at most half a quantisation step per element
    err = np.abs(np.asarray(dequantise_kv_rows(q, scale)) - np.asarray(x))
    assert (err <= 0.5 * np.asarray(scale)[..., None, None] + 1e-7).all()
    # all-zero rows stay finite and decode to exactly zero
    qz, sz = quantise_kv_rows(jnp.zeros((1, 2, 4, 16)))
    assert np.isfinite(np.asarray(sz)).all()
    np.testing.assert_array_equal(
        np.asarray(dequantise_kv_rows(qz, sz)), np.zeros((1, 2, 4, 16))
    )


def test_int8_cache_layout_and_validation(smoke_setup):
    cfg, _ = smoke_setup
    cache = models.init_paged_cache(cfg, 5, 8, "int8")
    leaf = cache[0]
    assert leaf["k"].dtype == jnp.int8 and leaf["v"].dtype == jnp.int8
    # scales: [m, P, page_size] riding the same pytree as the pages
    assert leaf["k_scale"].shape == leaf["k"].shape[:3]
    assert leaf["k_scale"].dtype == jnp.float32
    with pytest.raises(ValueError, match="kv_dtype"):
        models.init_paged_cache(cfg, 5, 8, "fp8")
    with pytest.raises(Exception):
        PagePool(4, 4, kv_dtype="fp8")
    # matched-memory arithmetic: int8 page ~1/4 the bytes (+ scale overhead)
    b32 = page_bytes(8, 4, 16, "fp32")
    b8 = page_bytes(8, 4, 16, "int8")
    assert b32 == 2 * 8 * 4 * 16 * 4
    assert b8 == 2 * 8 * 4 * 16 + 2 * 8 * 4
    assert 3.0 < b32 / b8 < 4.0


# ------------------------------------------------------- kernel vs oracle
def _quantised_pages(rng, P, ps, KH, dh):
    k = jnp.asarray(rng.normal(size=(P, ps, KH, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(P, ps, KH, dh)), jnp.float32)
    kq, ks = quantise_kv_rows(k)
    vq, vs = quantise_kv_rows(v)
    return kq, vq, ks, vs


def test_int8_decode_kernel_matches_oracle():
    from repro.kernels import (
        paged_decode_attention_int8,
        paged_decode_attention_int8_reference,
    )

    rng = np.random.default_rng(3)
    for (B, H, KH, dh, ps, PB) in [
        (2, 8, 4, 64, 8, 4),
        (1, 4, 4, 32, 16, 2),
    ]:
        P = 1 + B * PB
        q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
        kq, vq, ks, vs = _quantised_pages(rng, P, ps, KH, dh)
        perm = rng.permutation(np.arange(1, P))
        bt = jnp.asarray(perm.reshape(B, PB), jnp.int32)
        pos = jnp.asarray(rng.integers(0, ps * PB, B), jnp.int32)
        for kw in ({}, {"window": 9}, {"softcap": 10.0}):
            ref = paged_decode_attention_int8_reference(
                q, kq, vq, ks, vs, bt, pos, **kw
            )
            out = paged_decode_attention_int8(
                q, kq, vq, ks, vs, bt, pos, interpret=True, **kw
            )
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), atol=2e-6
            )


def test_int8_prefill_kernel_matches_oracle():
    from repro.kernels import (
        paged_prefill_attention_int8,
        paged_prefill_attention_int8_reference,
        paged_verify_attention_int8,
    )

    assert paged_verify_attention_int8 is paged_prefill_attention_int8
    rng = np.random.default_rng(4)
    for (B, H, KH, dh, ps, PB, C) in [
        (2, 8, 4, 64, 8, 4, 8),
        (1, 4, 2, 32, 8, 4, 16),
    ]:
        P = 1 + B * PB
        q = jnp.asarray(rng.normal(size=(B, C, H, dh)), jnp.float32)
        kq, vq, ks, vs = _quantised_pages(rng, P, ps, KH, dh)
        perm = rng.permutation(np.arange(1, P))
        bt = jnp.asarray(perm.reshape(B, PB), jnp.int32)
        start = jnp.asarray(rng.integers(0, ps * PB - C + 1, B), jnp.int32)
        for kw in ({}, {"window": 9}, {"softcap": 10.0}):
            ref = paged_prefill_attention_int8_reference(
                q, kq, vq, ks, vs, bt, start, **kw
            )
            out = paged_prefill_attention_int8(
                q, kq, vq, ks, vs, bt, start, interpret=True, **kw
            )
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), atol=2e-6
            )


# ------------------------------------------ model-level int8 equivalences
def test_int8_chunked_prefill_matches_int8_sequential_bitwise(smoke_setup):
    """The §10 bitwise contract survives quantisation: int8 chunked
    ingestion writes the same quantised bits + scales (shared
    quantise_kv_rows) and reads the same dequantised values as int8
    token-by-token decode — identical cache leaves and priming logits."""
    cfg, params = smoke_setup
    ps, PB = 4, 8
    seq_cache = models.init_paged_cache(cfg, 1 + PB, ps, "int8")
    chk_cache = models.init_paged_cache(cfg, 1 + PB, ps, "int8")
    bt = jnp.asarray(1 + np.arange(PB).reshape(1, PB), jnp.int32)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 16)

    dstep = jax.jit(
        lambda p, c, t, po, b: models.paged_decode_step(cfg, p, c, t, po, b)
    )
    for i, t in enumerate(prompt):
        ld, seq_cache = dstep(
            params, seq_cache, jnp.asarray([[t]], jnp.int32),
            jnp.asarray([i], jnp.int32), bt,
        )

    pf = jax.jit(
        lambda p, c, t, s, b, l: models.paged_prefill_step(
            cfg, p, c, t, s, b, l
        )
    )
    cur = 0
    for chunk in (8, 8):
        tok = np.zeros((1, 8), np.int32)
        tok[0, :chunk] = prompt[cur : cur + chunk]
        lc, chk_cache = pf(
            params, chk_cache, jnp.asarray(tok),
            jnp.asarray([cur], jnp.int32), bt,
            jnp.asarray([chunk], jnp.int32),
        )
        cur += chunk

    for a, b in zip(jax.tree.leaves(seq_cache), jax.tree.leaves(chk_cache)):
        # exclude the null page: padding rows scribble it by design
        np.testing.assert_array_equal(np.asarray(a)[:, 1:], np.asarray(b)[:, 1:])
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(lc))


def test_int8_logit_drift_vs_fp32_bounded(smoke_setup):
    """Acceptance (ISSUE 5): teacher-forcing one shared-prefix stream
    through fp32 and int8 pools, the greedy logits drift by less than the
    stated bound — per-page absmax scales keep quantisation error far
    below the decision margins of the head."""
    cfg, params = smoke_setup
    ps, PB = 8, 8
    bt = jnp.asarray(1 + np.arange(PB).reshape(1, PB), jnp.int32)
    c32 = models.init_paged_cache(cfg, 1 + PB, ps)
    c8 = models.init_paged_cache(cfg, 1 + PB, ps, "int8")
    dstep = jax.jit(
        lambda p, c, t, po, b: models.paged_decode_step(cfg, p, c, t, po, b)
    )
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 16)  # the common prefix
    tail = rng.integers(0, cfg.vocab_size, 16)
    drift = 0.0
    argmax_flips = 0
    for i, t in enumerate(list(shared) + list(tail)):
        l32, c32 = dstep(
            params, c32, jnp.asarray([[t]], jnp.int32),
            jnp.asarray([i], jnp.int32), bt,
        )
        l8, c8 = dstep(
            params, c8, jnp.asarray([[t]], jnp.int32),
            jnp.asarray([i], jnp.int32), bt,
        )
        a, b = np.asarray(l32)[0], np.asarray(l8)[0]
        drift = max(drift, float(np.abs(a - b).max()))
        argmax_flips += int(a.argmax() != b.argmax())
    assert drift < LOGIT_DRIFT_BOUND, drift
    assert argmax_flips == 0  # greedy stream unchanged on this workload


# ------------------------------------------------ scales ride page cycle
def test_scale_cow_on_fork(smoke_setup):
    """Per-page scales are COW-copied alongside the pages: after fork +
    ensure_writable, the private copy carries the original's quantised
    bits *and* scales (copy_cache_pages moves every leaf with a page
    axis), so the forked request reads identical dequantised KV."""
    cfg, _ = smoke_setup
    pool = PagePool(6, 4, kv_dtype="int8")
    cache = models.init_paged_cache(cfg, 7, 4, "int8")
    # write recognisable bits + scales into page 1
    cache = jax.tree.map(
        lambda t: t.at[:, 1].set(jnp.ones_like(t[:, 1])), cache
    )
    copies: list[tuple[int, int]] = []

    def copy_page(src: int, dst: int) -> None:
        nonlocal cache
        copies.append((src, dst))
        cache = models.copy_cache_pages(cache, src, dst)

    table = BlockTable(pool=pool)
    assert table.append_page()  # page 1
    table.num_tokens = 2
    fork = table.fork()
    assert pool.refcount(1) == 2
    # the fork writes position 2 -> COW into a fresh page
    assert fork.ensure_writable(2, copy_page)
    assert copies and copies[0][0] == 1
    dst = copies[0][1]
    for leaf in jax.tree.leaves(cache):
        np.testing.assert_array_equal(
            np.asarray(leaf)[:, dst], np.asarray(leaf)[:, 1]
        )
    fork.release()
    table.release()
    pool.check()


def test_scale_overwrite_after_trim_and_realloc(smoke_setup):
    """trim() releases pages back to the pool (DESIGN.md §11); a realloc's
    next committed write overwrites the stale quantised bits *and* stale
    scales in one scatter, so recycled pages never leak a previous
    occupant's dequantisation into live reads."""
    cfg, params = smoke_setup
    ps = 4
    pool = PagePool(2, ps, kv_dtype="int8")
    cache = models.init_paged_cache(cfg, 3, ps, "int8")
    dstep = jax.jit(
        lambda p, c, t, po, b: models.paged_decode_step(cfg, p, c, t, po, b)
    )
    table = BlockTable(pool=pool)
    assert table.ensure_capacity(ps)  # 2 pages
    bt = np.zeros((1, 2), np.int32)
    bt[0, : table.num_pages] = table.pages
    # write rows 0..ps (spilling into page 2), as a verify window would
    for i in range(ps + 1):
        _, cache = dstep(
            params, cache, jnp.asarray([[7]], jnp.int32),
            jnp.asarray([i], jnp.int32), jnp.asarray(bt),
        )
    second = table.pages[1]
    stale_scale = np.asarray(cache[0]["k_scale"])[0, second].copy()
    assert stale_scale[0] > 0  # the spilled row wrote a real scale
    # rollback: the verify window collapsed back inside page 1
    assert table.trim(1) == 1
    assert pool.pages_free == 1
    # a new request grabs the recycled page and writes its own row 0
    other = BlockTable(pool=pool)
    assert other.append_page()
    assert other.pages[0] == second
    bt2 = np.array([[second, 0]], np.int32)
    _, cache = dstep(
        params, cache, jnp.asarray([[9]], jnp.int32),
        jnp.asarray([0], jnp.int32), jnp.asarray(bt2),
    )
    fresh_scale = np.asarray(cache[0]["k_scale"])[0, second]
    assert fresh_scale[0] != stale_scale[0]  # overwritten, not reused
    # untouched offsets still hold stale garbage — masked by position, by
    # design: released-page hygiene is overwrite-on-write, never a branch
    other.release()
    table.release()
    pool.check()


# ------------------------------------------------------------- end to end
def test_int8_stream_matches_fp32_tokens(smoke_setup):
    """Greedy streams through the int8 pool match the fp32 pool on the
    smoke workload (drift << decision margins), with zero compiles after
    warmup on both — the serving-level face of the drift bound."""
    from repro.runtime.serve import run_paged_stream

    cfg, params = smoke_setup

    def reqs():
        rng = np.random.default_rng(0)
        return [
            Request(
                rid=i, new_tokens=4, greedy=True, arrival_s=0.0,
                prompt=tuple(
                    int(x) for x in rng.integers(0, cfg.vocab_size, 12)
                ),
            )
            for i in range(3)
        ]

    reports = {}
    streams = {}
    for dt in ("fp32", "int8"):
        reset_entry_points()
        eng = Engine(
            cfg,
            params,
            EngineConfig(
                max_len=32, batch_quantum=2, max_batch=4, page_size=8,
                num_pages=20, prefill_chunk=8, kv_dtype=dt,
            ),
        )
        rs = reqs()
        reports[dt] = run_paged_stream(eng, rs, slots=4)
        streams[dt] = [r.tokens for r in rs]
        eng.close()
    assert reports["int8"]["finished"] == 3
    assert reports["int8"]["compiles_after_warmup"] == 0
    assert reports["int8"]["kv_dtype"] == "int8"
    assert streams["int8"] == streams["fp32"]


# ------------------------------------------------- quantised draft views
def test_int8_draft_logit_drift_bounded(smoke_setup):
    """Satellite (ISSUE 9): the truncated-layer draft view served from an
    int8 page pool drifts from its fp32 twin by less than the stated
    bound — the draft only *proposes*; fp32 verify decides — but the
    proposal distribution must stay close or acceptance collapses."""
    cfg, params = smoke_setup
    dcfg, dparams = models.draft_view(cfg, params, draft_layers=1)
    ps, PB = 8, 4
    bt = jnp.asarray(1 + np.arange(PB).reshape(1, PB), jnp.int32)
    c32 = models.init_paged_cache(dcfg, 1 + PB, ps)
    c8 = models.init_paged_cache(dcfg, 1 + PB, ps, "int8")
    dstep = jax.jit(
        lambda p, c, t, po, b: models.paged_decode_step(dcfg, p, c, t, po, b)
    )
    rng = np.random.default_rng(1)
    drift = 0.0
    for i, t in enumerate(rng.integers(0, cfg.vocab_size, 24)):
        l32, c32 = dstep(
            dparams, c32, jnp.asarray([[t]], jnp.int32),
            jnp.asarray([i], jnp.int32), bt,
        )
        l8, c8 = dstep(
            dparams, c8, jnp.asarray([[t]], jnp.int32),
            jnp.asarray([i], jnp.int32), bt,
        )
        drift = max(
            drift, float(np.abs(np.asarray(l32) - np.asarray(l8)).max())
        )
    assert drift < LOGIT_DRIFT_BOUND, drift


def test_int8_draft_pairs_with_fp32_verify_stream(smoke_setup):
    """End-to-end: spec decoding with an int8 draft pool under an fp32
    verify pool emits the *same greedy stream* as with an fp32 draft pool
    — the verify lane owns correctness, the quantised draft only changes
    the proposal cost — with zero compiles after warmup, the draft lanes
    actually exercised, and no acceptance degradation vs the fp32 draft.
    (Greedy spec == plain greedy is test_specdec's invariant; with the
    int8 stream equal to the fp32 stream it carries over transitively.)"""
    from repro.runtime.serve import run_paged_stream

    cfg, params = smoke_setup

    def reqs():
        rng = np.random.default_rng(0)
        return [
            Request(
                rid=i, new_tokens=6, greedy=True, arrival_s=0.0,
                prompt=tuple(
                    int(x) for x in rng.integers(0, cfg.vocab_size, 12)
                ),
            )
            for i in range(3)
        ]

    streams, spec = {}, {}
    for ddt in ("fp32", "int8"):
        reset_entry_points()
        eng = Engine(
            cfg,
            params,
            EngineConfig(
                max_len=64, batch_quantum=2, max_batch=4, page_size=8,
                num_pages=40, prefill_chunk=8, spec_k=2, draft_layers=1,
                draft_kv_dtype=ddt,
            ),
        )
        rs = reqs()
        rep = run_paged_stream(eng, rs, slots=4)
        assert rep["finished"] == 3
        assert rep["compiles_after_warmup"] == 0
        assert rep["spec"]["drafted_tokens"] > 0  # the draft really ran
        streams[ddt] = [r.tokens for r in rs]
        spec[ddt] = rep["spec"]
        eng.close()
    assert streams["int8"] == streams["fp32"]
    # quantising the draft pool didn't change what it proposed: same
    # drafted/accepted counts, so same acceptance rate (no collapse)
    assert spec["int8"]["drafted_tokens"] == spec["fp32"]["drafted_tokens"]
    assert spec["int8"]["accepted_tokens"] == spec["fp32"]["accepted_tokens"]


def test_int8_draft_dtype_must_be_warmed(smoke_setup):
    """A draft pool dtype outside the warm ladder is refused up front —
    a cold draft dtype would compile mid-stream."""
    cfg, params = smoke_setup
    reset_entry_points()
    eng = Engine(
        cfg,
        params,
        EngineConfig(
            max_len=64, batch_quantum=2, max_batch=4, page_size=8,
            num_pages=40, prefill_chunk=8, spec_k=2, draft_layers=1,
        ),
    )
    with pytest.raises(ValueError, match="draft_kv_dtype"):
        eng.paged_continuous(slots=4, draft_kv_dtype="int8")
    eng.close()
