"""Scheduler + continuous batching tests (runtime/scheduler.py, DESIGN.md §4):
admission/bucketing, slot join/leave correctness vs a naive per-request loop,
and the zero-recompile contract for mixed greedy/sample streams."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core import reset_entry_points
from repro.runtime.scheduler import (
    ContinuousBatcher,
    Request,
    RequestQueue,
    form_bursts,
    latency_report,
    poisson_arrivals,
)
from repro.runtime.serve import (
    GREEDY,
    SAMPLE,
    Engine,
    EngineConfig,
    run_continuous_stream,
)


# ----------------------------------------------------------- queue/arrivals
def test_poisson_arrivals_shape():
    reqs = poisson_arrivals(
        50, 100.0, seed=3, tokens_mean=8, tokens_max=32, vocab=128
    )
    assert len(reqs) == 50
    arr = [r.arrival_s for r in reqs]
    assert arr == sorted(arr) and arr[0] > 0
    assert all(1 <= r.new_tokens <= 32 for r in reqs)
    assert all(0 <= r.first_token < 128 for r in reqs)
    modes = {r.greedy for r in reqs}
    assert modes == {True, False}  # a mixed stream


def test_queue_pop_due_ordering_and_limit():
    reqs = [
        Request(rid=i, new_tokens=1, arrival_s=t)
        for i, t in enumerate([0.3, 0.1, 0.2, 0.9])
    ]
    q = RequestQueue(reqs)
    assert len(q) == 4
    assert q.next_arrival() == pytest.approx(0.1)
    due = q.pop_due(0.25, limit=1)
    assert [r.rid for r in due] == [1]
    due = q.pop_due(0.35)
    assert [r.rid for r in due] == [2, 0]  # arrival order
    assert q.pop_due(0.5) == []
    assert len(q) == 1


def test_form_bursts_groups_by_mode_and_buckets():
    reqs = [
        Request(rid=i, new_tokens=1, greedy=(i % 3 != 0)) for i in range(10)
    ]
    bursts = form_bursts(reqs, quantum=4, max_batch=8)
    for bucket, greedy, chunk in bursts:
        assert all(r.greedy == greedy for r in chunk)
        assert bucket % 4 == 0 and bucket >= len(chunk)
    assert sum(len(c) for _, _, c in bursts) == 10


# --------------------------------------- batcher bookkeeping (no model/jit)
def _fake_step(cache, tok, pos, active, temps, greedy, keys):
    """Deterministic stand-in for the compiled slot step: next = tok+1."""
    nxt = tok[:, 0] + 1
    return nxt, cache, pos + active.astype(jnp.int32), keys


def test_batcher_join_leave_bookkeeping():
    cb = ContinuousBatcher(
        step=_fake_step, num_slots=2, max_len=16, cache=None, seed=0
    )
    r0 = Request(rid=0, new_tokens=3, first_token=10)
    r1 = Request(rid=1, new_tokens=1, first_token=20)
    assert cb.admit([r0, r1], now=0.0) == 2
    assert cb.free_slots == 0
    done = cb.step(now=1.0)
    assert done == [r1] and r1.t_done == 1.0  # r1 finished, slot freed
    assert cb.free_slots == 1
    r2 = Request(rid=2, new_tokens=2, first_token=30)
    cb.admit([r2], now=1.5)
    while cb.has_work:
        cb.step(now=2.0)
    assert r0.tokens == [11, 12, 13]  # fake step: +1 per token
    assert r2.tokens == [31, 32]
    assert cb.stats.finished == 3 and cb.stats.admitted == 3
    assert cb.stats.tokens == 6


def test_batcher_admission_guards():
    cb = ContinuousBatcher(
        step=_fake_step, num_slots=1, max_len=4, cache=None
    )
    with pytest.raises(ValueError, match="max_len"):
        cb.admit([Request(rid=0, new_tokens=5)])
    cb.admit([Request(rid=1, new_tokens=1)])
    with pytest.raises(RuntimeError, match="free slot"):
        cb.admit([Request(rid=2, new_tokens=1)])


def test_latency_report_percentiles():
    reqs = []
    for i in range(10):
        r = Request(rid=i, new_tokens=1, arrival_s=0.0)
        r.tokens = [1]
        r.t_done = 0.1 * (i + 1)
        reqs.append(r)
    rep = latency_report(reqs)
    assert rep["finished"] == 10 and rep["tokens"] == 10
    assert rep["p50_ms"] <= rep["p95_ms"] <= rep["p99_ms"]


# ------------------------------------------------------- model-level (smoke)
@pytest.fixture(scope="module")
def engine():
    reset_entry_points()
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, Engine(
        cfg, params, EngineConfig(max_len=32, batch_quantum=4, max_batch=4)
    )


def _greedy_reqs(lengths, first_tokens, t0=0.0):
    return [
        Request(rid=i, new_tokens=n, greedy=True, first_token=f, arrival_s=t0)
        for i, (n, f) in enumerate(zip(lengths, first_tokens))
    ]


def test_continuous_join_leave_matches_sequential(engine):
    """Overlapped slot occupancy == one-request-at-a-time (same executable):
    a slot's stream is isolated from joins/leaves in other slots."""
    cfg, eng = engine
    lengths, firsts = [6, 3, 5, 2], [5, 9, 13, 17]

    cb = eng.continuous(slots=4, seed=0)
    overlapped = _greedy_reqs(lengths, firsts)
    cb.admit(overlapped, now=0.0)
    while cb.has_work:
        cb.step()

    sequential = _greedy_reqs(lengths, firsts)
    cb2 = eng.continuous(slots=4, seed=0)
    for r in sequential:
        cb2.admit([r], now=0.0)
        while cb2.has_work:
            cb2.step()

    for a, b in zip(overlapped, sequential):
        assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)


def test_continuous_greedy_matches_burst_engine(engine):
    """A lone greedy request in the batcher == the per-burst hot loop row."""
    cfg, eng = engine
    info = eng.set_mode(batch=4, sampling=GREEDY)
    b = info["bucket"]
    first = np.zeros((b, 1), np.int32)
    first[0, 0] = 11
    cache = models.init_cache(cfg, b, eng.ecfg.max_len)
    toks, _ = eng.decode_loop(cache, jnp.asarray(first), 0, 5)

    cb = eng.continuous(slots=b)
    req = Request(rid=0, new_tokens=5, greedy=True, first_token=11)
    cb.admit([req])
    while cb.has_work:
        cb.step()
    assert req.tokens == [int(t) for t in toks[0]]


def test_mixed_stream_zero_recompiles_after_warmup(engine):
    """The acceptance contract: greedy/sample mix never touches the cold
    path once the bucket executable exists."""
    cfg, eng = engine
    eng.continuous(slots=4)  # warmup compile for this bucket size
    compiles_warm = eng._decode.stats.misses
    reqs = poisson_arrivals(
        16, 500.0, seed=7, tokens_mean=4, tokens_max=16,
        sample_frac=0.5, vocab=cfg.vocab_size,
    )
    assert {r.greedy for r in reqs} == {True, False}
    rep = run_continuous_stream(eng, reqs, slots=4)
    assert rep["finished"] == 16
    assert eng._decode.stats.misses == compiles_warm
    assert rep["compiles_after_warmup"] == 0


def test_sampled_slots_respect_temperature_isolation(engine):
    """Two sampling requests with different keys produce independent
    streams; a greedy request in the same bucket stays deterministic."""
    cfg, eng = engine
    cb = eng.continuous(slots=4, seed=123)
    reqs = [
        Request(rid=0, new_tokens=8, greedy=True, first_token=3),
        Request(rid=1, new_tokens=8, greedy=False, temperature=1.0, first_token=3),
        Request(rid=2, new_tokens=8, greedy=False, temperature=1.0, first_token=3),
    ]
    cb.admit(reqs)
    while cb.has_work:
        cb.step()
    # greedy row reproducible across runs
    cb2 = eng.continuous(slots=4, seed=456)
    req_g = Request(rid=0, new_tokens=8, greedy=True, first_token=3)
    cb2.admit([req_g])
    while cb2.has_work:
        cb2.step()
    assert req_g.tokens == reqs[0].tokens
    # distinct per-slot keys -> (overwhelmingly) distinct sampled streams
    assert reqs[1].tokens != reqs[2].tokens


def test_decode_loop_zero_tokens_guard(engine):
    cfg, eng = engine
    info = eng.set_mode(batch=4, sampling=GREEDY)
    b = info["bucket"]
    cache = models.init_cache(cfg, b, eng.ecfg.max_len)
    toks, cache2 = eng.decode_loop(
        cache, jnp.zeros((b, 1), jnp.int32), 0, 0
    )
    assert toks.shape == (b, 0)
    assert cache2 is cache  # untouched


def test_engine_hysteresis_under_mode_oscillation():
    """With hysteresis=2, alternating greedy/sample bursts are served from
    the table — the hot slot never thrashes (paper Fig. 13 as policy)."""
    reset_entry_points()
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(
        cfg,
        params,
        EngineConfig(max_len=16, batch_quantum=4, max_batch=4, hysteresis=2),
    )
    eng.set_mode(batch=4, sampling=GREEDY, warm=False)
    eng.set_mode(batch=4, sampling=GREEDY, warm=False)  # slot captured
    assert eng._decode.current_key == ("burst", 4, GREEDY)
    rebinds = eng._decode.stats.rebinds
    for _ in range(4):
        eng.set_mode(batch=4, sampling=SAMPLE, warm=False)
        eng.set_mode(batch=4, sampling=GREEDY, warm=False)
    assert eng._decode.stats.rebinds == rebinds  # slot never moved
    assert eng._decode.current_key == ("burst", 4, GREEDY)
    # both modes still served correct executables (from the table)
    assert eng._current_key == ("burst", 4, GREEDY)
    eng.set_mode(batch=4, sampling=SAMPLE, warm=False)
    assert eng._current_key == ("burst", 4, SAMPLE)
    eng.close()
