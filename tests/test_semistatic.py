"""Unit + property tests for the paper's construct (BranchChanger et al.)."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import pytest as _pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container may lack hypothesis: skip only
    # the property tests, keep the plain unit tests runnable.
    def given(*_a, **_k):
        return lambda f: _pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

from repro.core import (
    BranchChanger,
    BranchChangerError,
    SpecTable,
    bucket_multiple,
    bucket_pow2,
    reset_entry_points,
    semi_static,
    semi_static_switch,
)


@pytest.fixture(autouse=True)
def _clean_registry():
    reset_entry_points()
    yield
    reset_entry_points()


def test_two_way_directions():
    bc = BranchChanger(lambda x: x + 1, lambda x: x - 1, name="t")
    bc.compile(jax.ShapeDtypeStruct((4,), jnp.float32))
    bc.set_direction(True)
    assert float(bc.branch(jnp.zeros(4))[0]) == 1.0
    bc.set_direction(False)
    assert float(bc.branch(jnp.zeros(4))[0]) == -1.0


def test_nary_switch():
    fns = [lambda x, i=i: x * 0 + i for i in range(5)]
    bc = BranchChanger(*fns, name="nary")
    bc.compile(jax.ShapeDtypeStruct((2,), jnp.float32))
    for i in [3, 0, 4, 2, 1]:
        bc.set_direction(i)
        assert float(bc.branch(jnp.zeros(2))[0]) == i


def test_uncompiled_eager_mode():
    bc = BranchChanger(lambda x: x * 2, lambda x: x * 3, name="eager")
    bc.set_direction(False)
    assert float(bc.branch(jnp.ones(()))) == 3.0


def test_duplicate_entry_point_guard():
    BranchChanger(lambda: 1, lambda: 2, name="dup")
    with pytest.raises(BranchChangerError, match="entry point"):
        BranchChanger(lambda: 1, lambda: 2, name="dup")


def test_close_releases_entry_point():
    bc = BranchChanger(lambda: 1, lambda: 2, name="dup2")
    bc.close()
    BranchChanger(lambda: 1, lambda: 2, name="dup2")  # no raise


def test_incompatible_signatures_guard():
    bc = BranchChanger(
        lambda x: x, lambda x: jnp.zeros((7,), jnp.int32), name="sig"
    )
    with pytest.raises(BranchChangerError, match="calling convention"):
        bc.compile(jax.ShapeDtypeStruct((4,), jnp.float32))


def test_direction_out_of_range():
    bc = BranchChanger(lambda: 1, lambda: 2, name="rng")
    with pytest.raises(BranchChangerError, match="out of range"):
        bc.set_direction(5)


def test_warm_counts_and_works():
    bc = BranchChanger(lambda x: x + 1, lambda x: x - 1, name="warm")
    bc.compile(jax.ShapeDtypeStruct((4,), jnp.float32))
    bc.set_direction(True, warm=True)
    assert bc.stats.warms == 1


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2), min_size=1, max_size=30))
def test_property_matches_lax_switch_oracle(directions):
    """Any direction sequence: semi-static result == lax.switch oracle."""
    reset_entry_points()
    fns = [lambda x: x + 1.0, lambda x: x * 2.0, lambda x: x - 3.0]
    bc = BranchChanger(*fns, name="prop")
    bc.compile(jax.ShapeDtypeStruct((3,), jnp.float32))
    x = jnp.arange(3.0)

    @jax.jit
    def oracle(i, x):
        return jax.lax.switch(i, fns, x)

    for d in directions:
        bc.set_direction(d)
        np.testing.assert_allclose(bc.branch(x), oracle(d, x), rtol=1e-6)


def test_single_writer_thread_safety():
    """Hot readers never observe a torn/invalid target while one writer flips."""
    bc = BranchChanger(lambda x: x * 0 + 1, lambda x: x * 0 + 2, name="mt")
    bc.compile(jax.ShapeDtypeStruct((2,), jnp.float32))
    bc.set_direction(True)
    stop = threading.Event()
    bad = []

    def writer():
        d = True
        while not stop.is_set():
            d = not d
            bc.set_direction(d)

    def reader():
        x = jnp.zeros(2)
        while not stop.is_set():
            v = float(bc.branch(x)[0])
            if v not in (1.0, 2.0):
                bad.append(v)

    ts = [threading.Thread(target=writer)] + [
        threading.Thread(target=reader) for _ in range(2)
    ]
    for t in ts:
        t.start()
    import time

    time.sleep(0.5)
    stop.set()
    for t in ts:
        t.join()
    assert not bad


def test_semi_static_stages_one_branch():
    """Only the selected branch's ops appear in the jaxpr (vs lax.cond)."""

    def heavy(x):
        return x @ x.T

    def light(x):
        return x

    def f_semi(x):
        return semi_static(False, heavy, light, x)

    def f_cond(x):
        return jax.lax.cond(False, heavy, light, x)

    x = jnp.ones((8, 8))
    semi_text = str(jax.make_jaxpr(f_semi)(x))
    cond_text = str(jax.make_jaxpr(f_cond)(x))
    assert "dot_general" not in semi_text  # untaken branch costs nothing
    assert "dot_general" in cond_text  # conditional stages both


def test_semi_static_rejects_tracers():
    with pytest.raises(BranchChangerError, match="host"):
        jax.jit(
            lambda p: semi_static(p, lambda: 1, lambda: 2)
        )(jnp.array(True))


def test_semi_static_switch_bounds():
    with pytest.raises(BranchChangerError, match="out of range"):
        semi_static_switch(3, [lambda: 1, lambda: 2])


def test_spec_table():
    t = SpecTable("t")
    calls = []
    exe = t.get_or_build("a", lambda: calls.append(1) or (lambda: 42))
    assert t.get_or_build("a", lambda: calls.append(1) or (lambda: 0))() == 42
    assert len(calls) == 1
    assert t.stats.misses == 1 and t.stats.hits == 1
    with pytest.raises(KeyError, match="precompile"):
        t.get("missing")


@given(st.integers(1, 4096))
@settings(max_examples=50, deadline=None)
def test_buckets(n):
    b = bucket_pow2(n, 8, 1024)
    assert b >= min(n, 1024) and b <= 1024 and (b & (b - 1)) == 0
    m = bucket_multiple(n, 4, 1024)
    assert m % 4 == 0 and m >= min(n, 4)
