"""Serving engine: bucketing, mode dispatch, hot-loop correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core import reset_entry_points
from repro.runtime.serve import GREEDY, SAMPLE, Engine, EngineConfig


@pytest.fixture(scope="module")
def engine():
    reset_entry_points()
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, Engine(
        cfg, params, EngineConfig(max_len=32, batch_quantum=2, max_batch=8)
    )


def test_set_mode_buckets_and_compiles(engine):
    cfg, eng = engine
    info = eng.set_mode(batch=3, sampling=GREEDY)
    assert info["bucket"] == 4
    assert ("burst", 4, GREEDY) in eng._decode
    # same bucket: cache hit, no new compile
    before = eng._decode.stats.misses
    eng.set_mode(batch=4, sampling=GREEDY)
    assert eng._decode.stats.misses == before


def test_decode_loop_produces_tokens(engine):
    cfg, eng = engine
    info = eng.set_mode(batch=2, sampling=GREEDY)
    b = info["bucket"]
    cache = models.init_cache(cfg, b, 32)
    toks, _ = eng.decode_loop(cache, jnp.zeros((b, 1), jnp.int32), 0, 5)
    assert toks.shape == (b, 5)
    assert toks.dtype == np.int32
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_greedy_matches_direct_decode(engine):
    """Engine hot path == calling models.decode_step + argmax directly."""
    cfg, eng = engine
    info = eng.set_mode(batch=2, sampling=GREEDY)
    b = info["bucket"]
    cache = models.init_cache(cfg, b, 32)
    first = jnp.zeros((b, 1), jnp.int32)
    toks, _ = eng.decode_loop(cache, first, 0, 4)

    cache2 = models.init_cache(cfg, b, 32)
    tok = first
    want = []
    for pos in range(4):
        logits, cache2 = models.decode_step(
            cfg, eng.params, cache2, tok, jnp.int32(pos)
        )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        want.append(np.asarray(tok[:, 0]))
    np.testing.assert_array_equal(toks, np.stack(want, 1))


def test_mode_switch_changes_sampling(engine):
    cfg, eng = engine
    eng.set_mode(batch=2, sampling=SAMPLE)
    assert eng._current_key[2] == SAMPLE
    eng.set_mode(batch=2, sampling=GREEDY)
    assert eng._current_key[2] == GREEDY
