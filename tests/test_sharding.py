"""Sharding rules: every assigned arch resolves on the production meshes.

Uses AbstractMesh (no devices needed) to validate the rule system: every
param/cache spec must respect divisibility, use each mesh axis at most once
per tensor, and give the big weights both a TP and an FSDP dim whenever the
arch's dims divide.
"""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import models
from repro.configs import ASSIGNED, get_config
from repro.distributed import sharding as shd
from repro.runtime import steps

# jax 0.4.x AbstractMesh signature: a tuple of (axis_name, size) pairs.
SINGLE = AbstractMesh((("data", 16), ("model", 16)))
MULTI = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _axes_of(spec_entry):
    if spec_entry is None:
        return ()
    if isinstance(spec_entry, str):
        return (spec_entry,)
    return tuple(spec_entry)


def _check_tree(spec_tree, shape_tree, mesh):
    leaves_spec = jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    leaves_shape = jax.tree.leaves(shape_tree)
    assert len(leaves_spec) == len(leaves_shape)
    for spec, leaf in zip(leaves_spec, leaves_shape):
        used = []
        for dim, entry in enumerate(spec):
            axes = _axes_of(entry)
            for a in axes:
                assert a in mesh.axis_names, (spec, leaf.shape)
                used.append(a)
            if axes:
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert leaf.shape[dim] % size == 0, (
                    spec, leaf.shape, dim, size,
                )
        assert len(used) == len(set(used)), f"axis reused: {spec}"


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_valid(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = shd.param_pspec_tree(shapes, mesh)
    _check_tree(specs, shapes, mesh)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_cache_specs_valid(arch):
    cfg = get_config(arch)
    for batch, seq in ((128, 32768), (1, 524288)):
        shapes = jax.eval_shape(
            lambda: models.init_cache(cfg, batch, seq)
        )
        specs = shd.cache_pspec_tree(cfg, shapes, MULTI)
        _check_tree(specs, shapes, MULTI)


def test_big_weights_get_tp_and_fsdp():
    cfg = get_config("deepseek-67b")
    shapes = jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = shd.param_pspec_tree(shapes, SINGLE)
    mlp = list(specs["blocks"][0]["mlp"]["w_gate"])  # [m, D, F]
    assert "model" in mlp and "data" in mlp


def test_qwen3_heads_fall_back_to_replicated():
    """40 heads don't divide 16 -> attention weights keep FSDP only."""
    cfg = get_config("qwen3-14b")
    shapes = jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = shd.param_pspec_tree(shapes, SINGLE)
    wq = specs["blocks"][0]["attn"]["wq"]  # [m, D, H=40, dh]
    flat = list(wq)
    assert "model" not in [a for a in flat if isinstance(a, str)]
    assert "data" in [a for a in flat if isinstance(a, str)]


def test_zero_over_pod_upgrades_moments():
    cfg = get_config("grok-1-314b")  # zero_over_pod=True
    shapes = jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0))
    )
    p_spec = shd.param_pspec_tree(shapes, MULTI)
    o_spec = shd.opt_pspec_tree(cfg, p_spec, shapes, MULTI)
    flat = jax.tree.leaves(o_spec, is_leaf=lambda x: isinstance(x, P))
    assert any(
        any("pod" in _axes_of(e) for e in spec) for spec in flat
    ), "no moment dim picked up the pod axis"


def test_data_pspec_batch_fallbacks():
    assert shd.data_pspec((256, 128), MULTI)[0] == ("pod", "data")
    assert shd.data_pspec((16, 128), MULTI)[0] == "data"  # 16 % 32 != 0
    assert shd.data_pspec((1, 128), MULTI)[0] is None


def test_hint_noop_without_mesh_context():
    x = jnp.ones((4, 4))
    assert shd.hint(x, "batch", None) is x


# ===================================================================
# Serving-mesh coordinate (DESIGN.md §16): "DPxMP" names, MeshPlan
# validation, and the in-process faces of the mesh dispatch axis.
# Multi-device rebind/identity runs live in subprocesses below (the
# pytest process deliberately sees 1 device).
# ===================================================================
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro import models
from repro.core import reset_entry_points
from repro.runtime.scheduler import Request
from repro.runtime.serve import Engine, EngineConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 4) -> str:
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.path.join(REPO, "src"),
    )
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=900, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mesh_name_parse_and_canonical():
    assert shd.parse_mesh_name("1x2") == (1, 2)
    assert shd.parse_mesh_name("2,2") == (2, 2)  # CLI comma form
    assert shd.mesh_name(2, 2) == "2x2"
    assert shd.mesh_name(*shd.parse_mesh_name("4,2")) == "4x2"
    with pytest.raises(ValueError):
        shd.parse_mesh_name("2x2x2")
    with pytest.raises(ValueError):
        shd.parse_mesh_name("0x2")
    with pytest.raises(ValueError):
        shd.parse_mesh_name("banana")


def test_mesh_plan_1x1_is_single_and_needs_no_devices():
    plan = shd.MeshPlan("1x1")
    assert plan.single and plan.num_devices == 1
    # a plan bigger than the visible fleet refuses to build its Mesh
    big = shd.MeshPlan("8x8")
    with pytest.raises(ValueError, match="devices"):
        _ = big.mesh


@pytest.fixture(scope="module")
def mesh_engine():
    reset_entry_points()
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(
        cfg,
        params,
        EngineConfig(
            max_len=32, batch_quantum=2, max_batch=4, page_size=8,
            num_pages=20, prefill_chunk=8,
        ),
    )
    yield cfg, eng
    eng.close()


def test_unwarmed_mesh_is_rejected(mesh_engine):
    """A mesh outside the warm ladder must be refused at construction —
    a cold topology would compile mid-stream, which the semi-static
    contract forbids."""
    cfg, eng = mesh_engine
    with pytest.raises(ValueError, match="warmed set"):
        eng.continuous(mesh="1x2")
    with pytest.raises(ValueError, match="warmed set"):
        eng.paged_continuous(mesh="2x2")


def test_set_mesh_validation_and_noop_flip(mesh_engine):
    cfg, eng = mesh_engine
    cb = eng.paged_continuous(slots=4)
    assert cb.mesh == "1x1" and cb.pool.shards == 1
    # same-topology flip (comma spelling): canonicalised, counted as no-op
    assert cb.set_mesh("1,1") == "1x1"
    assert cb.mesh == "1x1"
    assert eng.telemetry.registry.value("mesh_rebinds_total") == 0
    # a topology outside the warm ladder is refused mid-stream too
    with pytest.raises(ValueError, match="warmed set"):
        cb.set_mesh("2x2")
    assert eng.post_warmup_compiles == 0


def test_set_mesh_without_control_surface_raises(mesh_engine):
    cfg, eng = mesh_engine
    cb = eng.paged_continuous(slots=4)
    cb._mesh_ctl = None  # simulate a directly-constructed batcher
    with pytest.raises(RuntimeError, match="mesh control surface"):
        cb.set_mesh("1x1")


def _mesh_reqs_src(n=6, new_tokens=4, prompt_len=12):
    """Source snippet: deterministic greedy requests for subprocess runs.

    Indented to match the 8-space test snippets so textwrap.dedent in
    ``_run`` still strips a uniform prefix.
    """
    return f"""
        reqs = [Request(rid=i, new_tokens={new_tokens}, greedy=True,
                        arrival_s=0.0,
                        prompt=tuple(int(x) for x in rng.integers(
                            0, cfg.vocab_size, {prompt_len})))
                for i in range({n})]
"""


def test_paged_mesh_ladder_rebind_zero_compiles():
    """Tentpole acceptance: warm the 1x1/1x2/2x2 ladder, serve at 1x2,
    scale out to 2x2 mid-stream, then failover-shrink to 1x1 — every flip
    a hot-slot rebind, zero post-warmup compiles, all requests finish."""
    out = _run("""
        import jax, numpy as np
        from repro import models
        from repro.configs import get_config
        from repro.core import lanes as lanes_mod
        from repro.runtime.scheduler import Request
        from repro.runtime.serve import Engine, EngineConfig

        cfg = get_config('olmo-1b').smoke()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, EngineConfig(
            max_len=32, batch_quantum=2, max_batch=4, page_size=8,
            num_pages=20, prefill_chunk=8,
            mesh='1x2', meshes=('1x1', '2x2')))
        cb = eng.paged_continuous(slots=4)
        assert cb.mesh == '1x2'
        assert cb.pool.shards == 2  # max dp over the warm ladder (2x2)

        # round-trip coverage: every paged lane warmed at every mesh
        for m in ('1x1', '1x2', '2x2'):
            assert ('cbp', 4, 1, 'fp32', m) in eng._decode, m
            assert ('pf', 4, 8, 'fp32', m) in eng._decode, m

        rng = np.random.default_rng(0)
    """ + _mesh_reqs_src() + """
        done = []
        cb.admit(reqs[:2], now=0.0)
        for i in range(2):
            done += cb.step(now=0.1 * (i + 1))
        assert cb.set_mesh('2x2', now=0.3) == '2x2'  # scale out
        cb.admit(reqs[2:4], now=0.3)
        for i in range(12):
            if not cb.has_work:
                break
            done += cb.step(now=0.4 + 0.1 * i)
        assert cb.set_mesh('1x1', now=2.0) == '1x1'  # failover shrink
        cb.admit(reqs[4:], now=2.0)
        while cb.has_work:
            done += cb.step(now=3.0)
        assert len(done) == 6, len(done)
        assert all(len(r.tokens) == 4 for r in reqs)
        assert eng.post_warmup_compiles == 0, eng.post_warmup_compiles
        assert eng.telemetry.registry.value('mesh_rebinds_total') == 2
        print('OK')
    """, devices=4)
    assert "OK" in out


def test_dense_mesh_rebind_zero_compiles():
    """The dense engine's cb/pfd lanes carry the same mesh coordinate:
    1x1 <-> 1x2 flips mid-stream rebind the step executable without a
    compile, and every admitted request still finishes."""
    out = _run("""
        import jax, numpy as np
        from repro import models
        from repro.configs import get_config
        from repro.runtime.scheduler import Request
        from repro.runtime.serve import Engine, EngineConfig

        cfg = get_config('olmo-1b').smoke()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, EngineConfig(
            max_len=32, batch_quantum=2, max_batch=4, prefill_chunk=8,
            mesh='1x1', meshes=('1x2',)))
        cb = eng.continuous(slots=4)
        assert cb.mesh == '1x1'
        rng = np.random.default_rng(0)
    """ + _mesh_reqs_src(n=4) + """
        done = []
        cb.admit(reqs[:2], now=0.0)
        done += cb.step(now=0.1)
        assert cb.set_mesh('1x2', now=0.2) == '1x2'
        cb.admit(reqs[2:], now=0.2)
        for i in range(12):
            if not cb.has_work:
                break
            done += cb.step(now=0.3 + 0.1 * i)
        assert cb.set_mesh('1x1', now=2.0) == '1x1'
        while cb.has_work:
            done += cb.step(now=3.0)
        assert len(done) == 4, len(done)
        assert all(len(r.tokens) == 4 for r in reqs)
        assert eng.post_warmup_compiles == 0, eng.post_warmup_compiles
        print('OK')
    """, devices=2)
    assert "OK" in out


def test_1x1_greedy_bitwise_identity_vs_unsharded():
    """Acceptance: a 1x1-active engine whose warm ladder includes a
    dp-sharded standby (so the page pool is physically 2-sharded) emits
    byte-for-byte the same greedy streams as the plain unsharded engine."""
    out = _run("""
        import jax, numpy as np
        from repro import models
        from repro.configs import get_config
        from repro.core import reset_entry_points
        from repro.runtime.scheduler import Request
        from repro.runtime.serve import (
            Engine, EngineConfig, run_paged_stream,
        )

        cfg = get_config('olmo-1b').smoke()
        params = models.init_params(cfg, jax.random.PRNGKey(0))

        def reqs():
            rng = np.random.default_rng(0)
            return [Request(rid=i, new_tokens=4, greedy=True,
                            arrival_s=0.0,
                            prompt=tuple(int(x) for x in
                                         rng.integers(0, cfg.vocab_size, 12)))
                    for i in range(4)]

        streams, shards = {}, {}
        for tag, meshes in (('plain', ()), ('sharded', ('2x1',))):
            reset_entry_points()
            eng = Engine(cfg, params, EngineConfig(
                max_len=32, batch_quantum=2, max_batch=4, page_size=8,
                num_pages=20, prefill_chunk=8, mesh='1x1', meshes=meshes))
            rs = reqs()
            rep = run_paged_stream(eng, rs, slots=4)
            assert rep['compiles_after_warmup'] == 0
            streams[tag] = [r.tokens for r in rs]
            shards[tag] = rep['pool_shards']
            eng.close()
        assert shards == {'plain': 1, 'sharded': 2}, shards
        assert streams['sharded'] == streams['plain']
        print('OK')
    """, devices=2)
    assert "OK" in out
