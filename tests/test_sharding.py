"""Sharding rules: every assigned arch resolves on the production meshes.

Uses AbstractMesh (no devices needed) to validate the rule system: every
param/cache spec must respect divisibility, use each mesh axis at most once
per tensor, and give the big weights both a TP and an FSDP dim whenever the
arch's dims divide.
"""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import models
from repro.configs import ASSIGNED, get_config
from repro.distributed import sharding as shd
from repro.runtime import steps

# jax 0.4.x AbstractMesh signature: a tuple of (axis_name, size) pairs.
SINGLE = AbstractMesh((("data", 16), ("model", 16)))
MULTI = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _axes_of(spec_entry):
    if spec_entry is None:
        return ()
    if isinstance(spec_entry, str):
        return (spec_entry,)
    return tuple(spec_entry)


def _check_tree(spec_tree, shape_tree, mesh):
    leaves_spec = jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    leaves_shape = jax.tree.leaves(shape_tree)
    assert len(leaves_spec) == len(leaves_shape)
    for spec, leaf in zip(leaves_spec, leaves_shape):
        used = []
        for dim, entry in enumerate(spec):
            axes = _axes_of(entry)
            for a in axes:
                assert a in mesh.axis_names, (spec, leaf.shape)
                used.append(a)
            if axes:
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert leaf.shape[dim] % size == 0, (
                    spec, leaf.shape, dim, size,
                )
        assert len(used) == len(set(used)), f"axis reused: {spec}"


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_valid(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = shd.param_pspec_tree(shapes, mesh)
    _check_tree(specs, shapes, mesh)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_cache_specs_valid(arch):
    cfg = get_config(arch)
    for batch, seq in ((128, 32768), (1, 524288)):
        shapes = jax.eval_shape(
            lambda: models.init_cache(cfg, batch, seq)
        )
        specs = shd.cache_pspec_tree(cfg, shapes, MULTI)
        _check_tree(specs, shapes, MULTI)


def test_big_weights_get_tp_and_fsdp():
    cfg = get_config("deepseek-67b")
    shapes = jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = shd.param_pspec_tree(shapes, SINGLE)
    mlp = list(specs["blocks"][0]["mlp"]["w_gate"])  # [m, D, F]
    assert "model" in mlp and "data" in mlp


def test_qwen3_heads_fall_back_to_replicated():
    """40 heads don't divide 16 -> attention weights keep FSDP only."""
    cfg = get_config("qwen3-14b")
    shapes = jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = shd.param_pspec_tree(shapes, SINGLE)
    wq = specs["blocks"][0]["attn"]["wq"]  # [m, D, H=40, dh]
    flat = list(wq)
    assert "model" not in [a for a in flat if isinstance(a, str)]
    assert "data" in [a for a in flat if isinstance(a, str)]


def test_zero_over_pod_upgrades_moments():
    cfg = get_config("grok-1-314b")  # zero_over_pod=True
    shapes = jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0))
    )
    p_spec = shd.param_pspec_tree(shapes, MULTI)
    o_spec = shd.opt_pspec_tree(cfg, p_spec, shapes, MULTI)
    flat = jax.tree.leaves(o_spec, is_leaf=lambda x: isinstance(x, P))
    assert any(
        any("pod" in _axes_of(e) for e in spec) for spec in flat
    ), "no moment dim picked up the pod axis"


def test_data_pspec_batch_fallbacks():
    assert shd.data_pspec((256, 128), MULTI)[0] == ("pod", "data")
    assert shd.data_pspec((16, 128), MULTI)[0] == "data"  # 16 % 32 != 0
    assert shd.data_pspec((1, 128), MULTI)[0] is None


def test_hint_noop_without_mesh_context():
    x = jnp.ones((4, 4))
    assert shd.hint(x, "batch", None) is x
