"""Speculative-decoding tests (DESIGN.md §11): the truncated-layer draft
view, verify-lane logits vs sequential decode (all rows, bitwise), greedy
spec streams bit-for-bit equal to plain greedy streams (tokens and committed
cache bits after rollback, dense + paged), sampling-slot isolation, k-bucket
crossings rebinding without compiles, warmup completeness across every
lane/bucket crossing for both engines, and BlockTable.trim rollback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core import reset_entry_points
from repro.runtime.kvcache import PagePool
from repro.runtime.scheduler import LanePolicy, Request
from repro.runtime.serve import Engine, EngineConfig


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, *, spec_k, prefill_chunk=16, max_len=64, slots=4):
    reset_entry_points()
    return Engine(
        cfg,
        params,
        EngineConfig(
            max_len=max_len,
            batch_quantum=2,
            max_batch=slots,
            page_size=8,
            num_pages=40,
            prefill_chunk=prefill_chunk,
            spec_k=spec_k,
            draft_layers=1,
        ),
    )


def _prompt_reqs(cfg, n=3, prompt_len=20, new_tokens=8, seed=0, greedy=True):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i, new_tokens=new_tokens, greedy=greedy, arrival_s=0.0,
            prompt=tuple(
                int(x) for x in rng.integers(0, cfg.vocab_size, prompt_len)
            ),
        )
        for i in range(n)
    ]


# ------------------------------------------------------------- draft view
def test_draft_view_truncates_layers_and_shares_embed(smoke_setup):
    cfg, params = smoke_setup
    dcfg, dparams = models.draft_view(cfg, params, 1)
    assert dcfg.num_layers == cfg.period
    assert dparams["embed"] is params["embed"]  # shared, not copied
    assert dparams["head"] is params["head"]
    for db, tb in zip(dparams["blocks"], params["blocks"]):
        for dl, tl in zip(jax.tree.leaves(db), jax.tree.leaves(tb)):
            assert dl.shape[0] == 1
            np.testing.assert_array_equal(np.asarray(dl), np.asarray(tl[:1]))
    # a full-depth view is the target itself
    fcfg, _ = models.draft_view(cfg, params, 99)
    assert fcfg.num_layers == cfg.num_layers


# --------------------------------------------- verify rows == sequential
def test_verify_rows_match_sequential_decode_bitwise(smoke_setup):
    """Every verify-window row's logits are bit-for-bit the logits
    sequential decode would produce after feeding the earlier rows — the
    property that makes greedy speculation exactly greedy decode."""
    cfg, params = smoke_setup
    ps, PB = 4, 8
    seq_cache = models.init_paged_cache(cfg, 1 + PB, ps)
    vf_cache = models.init_paged_cache(cfg, 1 + PB, ps)
    bt = jnp.asarray(1 + np.arange(PB).reshape(1, PB), jnp.int32)
    rng = np.random.default_rng(1)
    window = rng.integers(0, cfg.vocab_size, 5)  # current token + 4 drafts

    dstep = jax.jit(
        lambda p, c, t, po, b: models.paged_decode_step(cfg, p, c, t, po, b)
    )
    seq_logits = []
    for i, t in enumerate(window):
        ld, seq_cache = dstep(
            params, seq_cache, jnp.asarray([[t]], jnp.int32),
            jnp.asarray([i], jnp.int32), bt,
        )
        seq_logits.append(np.asarray(ld)[0])

    vstep = jax.jit(
        lambda p, c, t, s, b, l: models.paged_verify_step(cfg, p, c, t, s, b, l)
    )
    lv, vf_cache = vstep(
        params, vf_cache, jnp.asarray(window.reshape(1, -1), jnp.int32),
        jnp.asarray([0], jnp.int32), bt, jnp.asarray([5], jnp.int32),
    )
    np.testing.assert_array_equal(np.asarray(lv)[0], np.stack(seq_logits))
    # identical cache bits too (all allocatable pages)
    for a, b in zip(jax.tree.leaves(seq_cache), jax.tree.leaves(vf_cache)):
        np.testing.assert_array_equal(np.asarray(a)[:, 1:], np.asarray(b)[:, 1:])


# ---------------------------------------------------- lane policy (host)
def test_lane_policy_budget_split_and_k_buckets():
    pol = LanePolicy(token_budget=12, prefill_chunk=32, spec_k=4)
    # no eligible spec work: the legacy one-token-per-decode-slot split
    plan = pol.plan(n_decode=2, max_remaining=0)
    assert plan.k == 0 and plan.chunk_budget == 10
    # speculation: each decoding slot budgets 1 + k
    plan = pol.plan(n_decode=2, max_remaining=10)
    assert plan.k == 4 and plan.chunk_budget == 12 - 2 * 5
    # k clamps to the log-sized buckets as the tail drains
    assert pol.plan(n_decode=1, max_remaining=3).k == 2
    assert pol.plan(n_decode=1, max_remaining=2).k == 1
    assert pol.plan(n_decode=1, max_remaining=1).k == 0
    # spec off: never a k
    off = LanePolicy(token_budget=12, prefill_chunk=32, spec_k=0)
    assert off.plan(n_decode=2, max_remaining=99).k == 0


# -------------------------------------------------- streams (bit-for-bit)
def test_spec_stream_matches_plain_greedy_both_engines(smoke_setup):
    """The acceptance contract: greedy speculative streams emit exactly the
    tokens plain greedy decode emits, for both engines, with zero compiles
    after warmup and at least one k-bucket crossing (requests drain)."""
    from repro.runtime.serve import run_continuous_stream, run_paged_stream

    cfg, params = smoke_setup
    for runner in (run_paged_stream, run_continuous_stream):
        spec_reqs = _prompt_reqs(cfg)
        plain_reqs = _prompt_reqs(cfg)
        eng = _engine(cfg, params, spec_k=2)
        rep_s = runner(eng, spec_reqs, slots=4)
        eng.close()
        eng = _engine(cfg, params, spec_k=0)
        rep_p = runner(eng, plain_reqs, slots=4)
        eng.close()

        assert rep_s["finished"] == len(spec_reqs)
        assert rep_s["compiles_after_warmup"] == 0
        assert rep_s["lane_steps"]["draft"] > 0
        assert rep_s["lane_steps"]["verify"] > 0
        assert rep_s["k_bucket_crossings"] >= 1
        assert rep_s["spec"]["drafted_tokens"] > 0
        for a, b in zip(spec_reqs, plain_reqs):
            assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)
        # fewer target steps than emitted tokens requires acceptance; with
        # random weights acceptance ~0, so only assert the accounting adds up
        st = rep_s["spec"]
        assert 0 <= st["accepted_tokens"] <= st["drafted_tokens"]


def test_spec_leaves_sampling_streams_unchanged(smoke_setup):
    """Sampling slots ride the verify lane with a length-1 window whose row
    0 *is* a decode step — same logits, same one-key-split-per-step
    cadence — so a mixed seed-token stream's sampled tokens match the
    non-speculative run bit-for-bit. (Prompted sampling streams keep §10's
    caveat: the spec budget changes chunk partitioning and with it the
    prefill-time PRNG path — same distribution, different draws.)"""
    from repro.runtime.serve import run_continuous_stream

    cfg, params = smoke_setup

    def mixed():
        reqs = [
            Request(rid=i, new_tokens=6, greedy=i < 2, temperature=1.0,
                    first_token=7 + i, arrival_s=0.0)
            for i in range(4)
        ]
        return reqs

    a, b = mixed(), mixed()
    eng = _engine(cfg, params, spec_k=2)
    run_continuous_stream(eng, a, slots=4)
    eng.close()
    eng = _engine(cfg, params, spec_k=0)
    run_continuous_stream(eng, b, slots=4)
    eng.close()
    for x, y in zip(a, b):
        assert x.tokens == y.tokens, (x.rid, x.greedy, x.tokens, y.tokens)


def test_spec_cache_bits_equal_after_rollback_dense(smoke_setup):
    """Cache bits, not just tokens: after the stream drains, the dense
    cache's committed region is bitwise what plain greedy wrote — rejected
    draft KV was overwritten or sits beyond the final frontier, which the
    verify window never exceeds."""
    cfg, params = smoke_setup
    reqs_s = _prompt_reqs(cfg, n=2, prompt_len=12, new_tokens=6)
    reqs_p = _prompt_reqs(cfg, n=2, prompt_len=12, new_tokens=6)

    eng = _engine(cfg, params, spec_k=2, slots=2)
    cb_s = eng.continuous(slots=2)
    cb_s.admit(reqs_s, now=0.0)
    while cb_s.has_work:
        cb_s.step()
    eng.close()

    eng = _engine(cfg, params, spec_k=0, slots=2)
    cb_p = eng.continuous(slots=2)
    cb_p.admit(reqs_p, now=0.0)
    while cb_p.has_work:
        cb_p.step()
    eng.close()

    for a, b in zip(reqs_s, reqs_p):
        assert a.tokens == b.tokens
    # final written frontier per slot: prompt + new - 1 positions written
    top = 12 + 6 - 1
    for a, b in zip(jax.tree.leaves(cb_s._cache), jax.tree.leaves(cb_p._cache)):
        np.testing.assert_array_equal(
            np.asarray(a)[:, :, :top], np.asarray(b)[:, :, :top]
        )


def test_spec_cache_bits_equal_after_rollback_paged(smoke_setup):
    """Paged edition, mid-stream: gather each request's committed logical
    KV through its block table and compare bitwise against a plain run at
    the same emitted count."""
    cfg, params = smoke_setup

    def gathered(cb, s, upto):
        table = cb._tables[s]
        out = []
        for leaf in jax.tree.leaves(cb._cache):
            pages = np.asarray(leaf)[:, table.pages]  # [m, P_req, ps, ...]
            m = pages.shape[0]
            logical = pages.reshape(m, -1, *pages.shape[3:])
            out.append(logical[:, :upto])
        return out

    def run(spec_k, steps=None):
        eng = _engine(cfg, params, spec_k=spec_k, slots=2)
        cb = eng.paged_continuous(slots=2)
        req = _prompt_reqs(cfg, n=1, prompt_len=12, new_tokens=12)[0]
        cb.admit([req], now=0.0)
        while cb.has_work and (steps is None or len(req.tokens) < steps):
            cb.step()
        eng.close()
        return cb, req

    cb_s, req_s = run(2, steps=6)  # mid-stream: rollback happened
    e = len(req_s.tokens)
    assert 0 < e < 12
    cb_p, req_p = run(0, steps=e)
    assert req_p.tokens[:e] == req_s.tokens[:e]
    # committed frontier: prompt-1 + emitted positions written
    upto = 12 - 1 + e
    for a, b in zip(gathered(cb_s, 0, upto), gathered(cb_p, 0, upto)):
        np.testing.assert_array_equal(a, b)


# --------------------------------------------------- warmup completeness
@pytest.mark.parametrize("engine_kind", ["paged", "dense"])
def test_warmup_completeness_all_lanes(smoke_setup, engine_kind):
    """Satellite regression: every lane/bucket crossing — decode capacity
    buckets, prefill chunk buckets, draft/verify k-buckets, the draft
    prompt mirror — is AOT-compiled at warmup; dispatching any of them
    afterwards moves no compile counter (future lanes can't silently skip
    warmup without failing this)."""
    cfg, params = smoke_setup
    eng = _engine(cfg, params, spec_k=2)
    s = 4
    if engine_kind == "paged":
        cb = eng.paged_continuous(slots=s)
        decode_keys = [
            ("cbp", s, pb, "fp32", "1x1") for pb in eng._pages_buckets()
        ]
        lane_dispatches = [
            lambda b=b: cb._prefill_dispatch(b) for b in eng._chunk_buckets()
        ]
        vkey = lambda k: ("vf", s, k, "fp32", "1x1")
    else:
        cb = eng.continuous(slots=s)
        decode_keys = [("cb", s, "1x1")]
        lane_dispatches = [
            lambda b=b: cb._prefill_dispatch(b) for b in eng._chunk_buckets()
        ]
        vkey = lambda k: ("vfd", s, k, "1x1")
    misses = eng._decode.stats.misses
    # every decode bucket, chunk bucket, and k bucket must already exist
    for key in decode_keys:
        eng._decode.dispatch(key)
    for fn in lane_dispatches:
        fn()
    for k in eng._k_buckets():
        cb._draft_dispatch(k)
        cb._verify_dispatch(k)
        cb._draft_prefill_dispatch(CHUNK_BUCKET := 8)
        assert vkey(k) in eng._decode
        assert ("dr", s, k, "fp32", "1x1") in eng._decode
    assert eng._decode.stats.misses == misses, (
        f"{engine_kind}: lane/bucket dispatch compiled after warmup "
        f"(keys: {eng._decode.cache.keys()})"
    )
    eng.close()


def test_k_crossing_rebinds_without_compiling(smoke_setup):
    """Draining requests shrink max_remaining, the LanePolicy drops k, and
    the crossing re-dispatches warmed executables: rebinds move, compiles
    don't."""
    from repro.runtime.serve import run_paged_stream

    cfg, params = smoke_setup
    reqs = _prompt_reqs(cfg, n=2, prompt_len=12, new_tokens=10)
    eng = _engine(cfg, params, spec_k=4)
    rep = run_paged_stream(eng, reqs, slots=2)
    eng.close()
    assert rep["k_bucket_crossings"] >= 2  # 4 -> 2 -> 1 as the tail drains
    assert rep["compiles_after_warmup"] == 0


# ------------------------------------------------------- kvcache rollback
def test_block_table_trim_releases_pages():
    from repro.runtime.kvcache import BlockTable, KVCacheError

    pool = PagePool(8, 4)
    table = BlockTable(pool=pool)
    assert table.ensure_capacity(15)  # 4 pages
    assert table.num_pages == 4 and pool.pages_in_use == 4
    # rollback to a 6-token frontier: keep pages 0-1, release 2-3
    table.num_tokens = 6
    assert table.trim(table.page_index(6) + 1) == 2
    assert table.num_pages == 2 and pool.pages_in_use == 2
    assert table.trim(5) == 0  # growing trim is a no-op
    with pytest.raises(KVCacheError):
        table.trim(-1)
    # shared pages: trim drops only this table's reference
    fork = table.fork()
    assert fork.trim(1) == 1
    assert pool.refcount(table.pages[1]) == 1
    fork.release()
    table.release()
    pool.check()
