"""Data pipeline, optimizer, checkpoint, fault-tolerance, collectives tests."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import pytest as _pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container may lack hypothesis: skip only
    # the property tests, keep the plain unit tests runnable.
    def given(*_a, **_k):
        return lambda f: _pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import reset_entry_points
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.collectives import (
    dequantize_tree,
    make_grad_compressor,
    quantize_tree,
)
from repro.ft.failover import (
    FailoverPlan,
    HeartbeatMonitor,
    StepTimeWatchdog,
)
from repro.optim import adamw


# ----------------------------------------------------------------------- data
def test_data_deterministic_and_host_sharded():
    cfg = get_config("olmo-1b").smoke()
    d = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=16, seed=3))
    b1 = d.batch_at(5)
    b2 = d.batch_at(5)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert not np.array_equal(b1["inputs"], d.batch_at(6)["inputs"])
    # host shards partition the global batch deterministically
    h0 = d.batch_at(5, host_id=0, num_hosts=2)
    h1 = d.batch_at(5, host_id=1, num_hosts=2)
    assert h0["inputs"].shape[0] == 4 and h1["inputs"].shape[0] == 4
    assert not np.array_equal(h0["inputs"], h1["inputs"])


def test_data_labels_are_shifted_tokens():
    cfg = get_config("olmo-1b").smoke()
    d = SyntheticLM(cfg, DataConfig(global_batch=2, seq_len=16))
    b = d.batch_at(0)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_data_padding_masks_labels():
    cfg = get_config("olmo-1b").smoke()
    d = SyntheticLM(cfg, DataConfig(global_batch=2, seq_len=16, pad_frac=0.25))
    b = d.batch_at(0)
    assert (b["labels"][:, -4:] == -1).all()


def test_prefetcher_yields_in_order():
    cfg = get_config("olmo-1b").smoke()
    src = SyntheticLM(cfg, DataConfig(global_batch=2, seq_len=8))
    pf = Prefetcher(src, start_step=10, depth=2)
    try:
        it = iter(pf)
        for want in (10, 11, 12):
            step, batch = next(it)
            assert step == want
            np.testing.assert_array_equal(
                batch["inputs"], src.batch_at(step)["inputs"]
            )
    finally:
        pf.close()


# ------------------------------------------------------------------ optimizer
def test_adamw_first_step_matches_hand_math():
    cfg = adamw.AdamWConfig(
        peak_lr=0.1, warmup_steps=0, total_steps=10, weight_decay=0.0,
        clip_norm=1e9, b1=0.9, b2=0.999, eps=0.0, min_lr_frac=1.0,
    )
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.5, -0.5])}
    state = adamw.init(params)
    new_p, new_state, metrics = adamw.update(cfg, grads, state, params)
    # bias-corrected first step: mhat=g, vhat=g^2 -> delta = sign(g)
    np.testing.assert_allclose(
        np.asarray(new_p["w"]), [1.0 - 0.1, 2.0 + 0.1], rtol=1e-5
    )
    assert int(new_state.step) == 1


def test_adamw_clips_global_norm():
    cfg = adamw.AdamWConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full((4,), 100.0)}
    st = adamw.init(params)
    _, _, metrics = adamw.update(cfg, grads, st, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(adamw.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    state = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.int32(7)}}
    mgr.save(3, state)
    mgr.save(7, jax.tree.map(lambda x: x + 1, state))
    assert mgr.list_steps() == [3, 7]
    step, restored = mgr.restore(jax.eval_shape(lambda: state))
    assert step == 7
    np.testing.assert_allclose(restored["a"], np.arange(6.0).reshape(2, 3) + 1)
    assert int(restored["n"]["b"]) == 8


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
    state = {"w": jnp.ones(8)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    mgr.wait()
    assert mgr.list_steps() == [3, 4]


def test_checkpoint_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore({"w": jnp.ones(2)})


def test_checkpoint_restart_resumes_training(tmp_path):
    """Train 2 steps, checkpoint, restart from disk, verify identical to
    an uninterrupted 4-step run (the restart contract)."""
    from repro import models
    from repro.runtime.steps import TrainState, make_train_fn

    cfg = get_config("olmo-1b").smoke()
    dcfg = DataConfig(global_batch=2, seq_len=8)
    data = SyntheticLM(cfg, dcfg)
    step_fn = jax.jit(make_train_fn(cfg, adamw.AdamWConfig(peak_lr=1e-3)))

    def fresh():
        p = models.init_params(cfg, jax.random.PRNGKey(0))
        return TrainState(params=p, opt=adamw.init(p))

    # uninterrupted
    s = fresh()
    for i in range(4):
        s, _ = step_fn(s, data.batch_at(i))
    want = s.params

    # interrupted + restored
    mgr = CheckpointManager(tmp_path, async_write=False)
    s = fresh()
    for i in range(2):
        s, _ = step_fn(s, data.batch_at(i))
    mgr.save(2, s)
    step0, s2 = mgr.restore(jax.eval_shape(fresh))
    for i in range(step0, 4):
        s2, _ = step_fn(s2, data.batch_at(i))
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        ),
        want,
        s2.params,
    )


# ------------------------------------------------------------- fault tolerance
def test_heartbeat_detects_stale_worker():
    mon = HeartbeatMonitor(["w0", "w1"], timeout_s=0.05)
    mon.beat("w0")
    time.sleep(0.08)
    mon.beat("w0")
    assert mon.failed() == ["w1"]


def test_watchdog_flags_stragglers():
    wd = StepTimeWatchdog(threshold=2.0, warmup=2)
    flags = [wd.observe(i, 0.1) for i in range(6)]
    assert not any(flags)
    assert wd.observe(6, 0.5)  # 5x the EMA
    assert wd.events


def test_failover_plan_flips_to_degraded():
    reset_entry_points()
    calls = []
    plan = FailoverPlan(
        healthy_fn=lambda x: ("healthy", x),
        degraded_fn=lambda x: ("degraded", x),
        reshard_fn=lambda s: s + 100,
        name="ft-test",
        on_failover=[lambda failed: calls.append(failed)],
    )
    try:
        mon = HeartbeatMonitor(["w0"], timeout_s=0.01)
        assert plan.step(1)[0] == "healthy"
        time.sleep(0.05)
        state = plan.check(mon, 1)
        assert plan.degraded and state == 101 and calls == [["w0"]]
        assert plan.step(1)[0] == "degraded"
        # idempotent: second check doesn't re-fail
        assert plan.check(mon, state) == state and plan.failovers == 1
    finally:
        plan.close()


# ------------------------------------------------------------------ compression
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_quantize_roundtrip_bounded_error(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_tree({"x": x})
    back = dequantize_tree(q, s)["x"]
    # error bounded by half a quantisation step
    step = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(back - x))) <= 0.51 * step + 1e-9


def test_error_feedback_accumulates_residual():
    compress, init_res = make_grad_compressor(bits=8, error_feedback=True)
    g = {"w": jnp.array([1.0, 1e-4])}  # tiny component would vanish alone
    r = init_res(g)
    total = jnp.zeros(2)
    for _ in range(200):
        ghat, r = compress(g, r)
        total = total + ghat["w"]
    # over many steps the mean compressed gradient approaches the true one
    # (the tiny component is below one quantisation step, so allow the
    # residual-carry variance: |err| <= step/sqrt(n)-ish)
    np.testing.assert_allclose(
        np.asarray(total) / 200, [1.0, 1e-4], rtol=0.05, atol=5e-5
    )
