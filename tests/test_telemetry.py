"""Flight recorder + metrics registry tests (DESIGN.md §14): ring-buffer
bounds and overflow accounting, emission ordering, the zero-event guarantee
when disabled, histogram percentile math against numpy, Prometheus text
exposition validity, Chrome-trace schema via chrome_trace/validate_trace,
the latency_report-derives-from-registry regression, the warmup rollover
boundary, burst/continuous report parity, per-DispatchKey compile reports,
and page-pool / d2h event emission."""

import json

import jax
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core import reset_entry_points
from repro.core.telemetry import (
    DEFAULT_MS_BUCKETS,
    FlightRecorder,
    Histogram,
    MetricsRegistry,
    Telemetry,
)
from repro.runtime.scheduler import Request, latency_report
from repro.runtime.serve import (
    Engine,
    EngineConfig,
    run_burst_stream,
    run_continuous_stream,
)
from repro.runtime.steps import pull_host
from repro.runtime.tracing import chrome_trace, validate_trace, write_trace


# ------------------------------------------------------------ flight recorder
def test_ring_buffer_bounds_and_overflow():
    rec = FlightRecorder(capacity=8, enabled=True)
    for i in range(20):
        rec.emit(f"e{i}", "scheduler", ts_ns=1000 + i)
    assert len(rec) == 8
    assert rec.emitted == 20
    assert rec.dropped == 12
    names = [e.name for e in rec.events()]
    assert names == [f"e{i}" for i in range(12, 20)]  # oldest survivors first
    ts = [e.ts_ns for e in rec.events()]
    assert ts == sorted(ts)  # emission order preserved across the wrap


def test_disabled_recorder_emits_nothing():
    rec = FlightRecorder(capacity=8, enabled=False)
    rec.emit("x", "scheduler")
    rec.complete("y", "scheduler", t0_ns=0)
    rec.counter("z", "page-pool", v=1.0)
    assert len(rec) == 0 and rec.emitted == 0
    tel = Telemetry()  # disabled is the default
    assert tel.trace_or_none() is None
    tel.enable()
    assert tel.trace_or_none() is tel.recorder
    tel.disable()
    assert tel.trace_or_none() is None


def test_recorder_clear_and_capacity_validation():
    rec = FlightRecorder(capacity=4, enabled=True)
    for i in range(6):
        rec.emit(f"e{i}", "scheduler")
    rec.clear()
    assert len(rec) == 0 and rec.emitted == 0 and rec.dropped == 0
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


# ----------------------------------------------------------------- histograms
def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    samples = rng.uniform(0.06, 9.5, size=5000)  # spans several buckets
    h = Histogram(DEFAULT_MS_BUCKETS)
    for s in samples:
        h.observe(float(s))
    assert h.count == len(samples)
    assert h.sum == pytest.approx(samples.sum())
    assert h.mean == pytest.approx(samples.mean())
    for p in (50, 95, 99):
        est = h.percentile(p)
        exact = float(np.percentile(samples, p))
        # linear interpolation is exact to within the containing bucket width
        idx = int(np.searchsorted(DEFAULT_MS_BUCKETS, exact))
        lo = 0.0 if idx == 0 else DEFAULT_MS_BUCKETS[idx - 1]
        hi = DEFAULT_MS_BUCKETS[min(idx, len(DEFAULT_MS_BUCKETS) - 1)]
        assert abs(est - exact) <= (hi - lo) + 1e-9, (p, est, exact)


def test_histogram_overflow_and_cumulative():
    h = Histogram((1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.counts == [1, 1, 1, 1]  # last is the +Inf overflow bucket
    cum = h.cumulative()
    assert cum == [(1.0, 1), (2.0, 2), (4.0, 3), (float("inf"), 4)]
    assert h.percentile(100) == 4.0  # overflow clamps to the last bound
    with pytest.raises(ValueError):
        Histogram((2.0, 1.0))


# ------------------------------------------------------------------- registry
def test_registry_instruments_and_labeled_values():
    reg = MetricsRegistry()
    reg.inc("lane_calls_total", lane="cb")
    reg.inc("lane_calls_total", 2, lane="pf")
    reg.set("pool_pages_free", 7.0)
    reg.observe("lane_step_ms", 1.5, lane="cb")
    assert reg.value("lane_calls_total", lane="cb") == 1
    assert reg.labeled_values("lane_calls_total", "lane") == {"cb": 1, "pf": 2}
    with pytest.raises(ValueError):  # kind mismatch on an existing family
        reg.gauge("lane_calls_total")
    snap = reg.snapshot()
    assert snap["counters"]["lane_calls_total"]
    assert snap["histograms"]["lane_step_ms"][0]["count"] == 1


def test_registry_rollover_keeps_cached_handles():
    reg = MetricsRegistry()
    c = reg.counter("lane_calls_total", lane="cb")
    h = reg.histogram("lane_step_ms", lane="cb")
    c.inc(5)
    h.observe(2.0)
    snap = reg.rollover("warmup")
    assert snap["counters"]["lane_calls_total"][0]["value"] == 5
    assert reg.sections["warmup"] is snap
    # instruments are reset *in place*: the cached handles stay live
    assert c.value == 0 and h.count == 0
    c.inc()
    h.observe(1.0)
    assert reg.value("lane_calls_total", lane="cb") == 1
    assert reg.snapshot()["sections"]["warmup"] is snap


def test_prometheus_exposition_parses():
    reg = MetricsRegistry()
    reg.inc("lane_calls_total", 3, lane="cb")
    reg.set("pool_pages_free", 5.0)
    for v in (0.5, 1.5, 30.0):
        reg.observe("lane_step_ms", v, lane='c"b\\x')  # label escaping
    text = reg.to_prometheus()
    lines = text.strip().splitlines()
    types = {}
    for line in lines:
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split()
            types[name] = kind
            continue
        assert not line.startswith("#")
        body, value = line.rsplit(" ", 1)
        float(value)  # every sample line ends in a number
    assert types == {
        "lane_calls_total": "counter",
        "pool_pages_free": "gauge",
        "lane_step_ms": "histogram",
    }
    # histogram export: cumulative buckets, +Inf == _count, _sum present
    bucket_lines = [l for l in lines if l.startswith("lane_step_ms_bucket")]
    cums = [int(l.rsplit(" ", 1)[1]) for l in bucket_lines]
    assert cums == sorted(cums)
    assert 'le="+Inf"' in bucket_lines[-1] and cums[-1] == 3
    assert any(l.startswith("lane_step_ms_sum") for l in lines)
    assert any(
        l.startswith("lane_step_ms_count") and l.endswith(" 3") for l in lines
    )


# ------------------------------------------------------------- trace exporter
def test_chrome_trace_schema(tmp_path):
    rec = FlightRecorder(capacity=64, enabled=True)
    rec.emit("rebind", "dispatcher", args={"key": "k"})
    rec.complete("lane_step", "lane:cb", t0_ns=rec.t0_ns)
    rec.counter("pool_occupancy", "page-pool", pages_in_use=3)
    trace = chrome_trace(rec)
    assert validate_trace(trace) == []
    tracks = {
        e["args"]["name"]
        for e in trace["traceEvents"]
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    assert {"dispatcher", "scheduler", "page-pool", "lane:cb"} <= tracks
    span = next(e for e in trace["traceEvents"] if e["ph"] == "X")
    assert "dur" in span and span["ts"] >= 0
    # round-trips through the file writer as valid JSON
    out = tmp_path / "trace.json"
    write_trace(str(out), rec)
    assert validate_trace(json.loads(out.read_text())) == []
    assert trace["otherData"]["emitted"] == 3


def test_validate_trace_flags_problems():
    assert validate_trace({"traceEvents": []}) != []
    bad = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 1,
                            "ts": 0.0}]}
    assert any("dur" in p for p in validate_trace(bad))


# ------------------------------------------------------------- serving stack
@pytest.fixture(scope="module")
def smoke_setup():
    cfg = get_config("olmo-1b").smoke()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests(n, tokens=4):
    return [
        Request(rid=i, first_token=1 + i, new_tokens=tokens, arrival_s=0.0)
        for i in range(n)
    ]


def test_continuous_report_derives_from_registry(smoke_setup):
    cfg, params = smoke_setup
    reset_entry_points()
    tel = Telemetry(enabled=True)
    eng = Engine(
        cfg, params, EngineConfig(max_len=32, batch_quantum=2, max_batch=2),
        telemetry=tel,
    )
    rep = run_continuous_stream(eng, _requests(4), slots=2)
    reg = tel.registry

    # latency_report's lane_calls IS the registry family (no parallel dict)
    assert rep["lane_calls"] == reg.labeled_values("lane_calls_total", "lane")
    assert rep["lane_calls"]["cb"] > 0

    # request-phase histograms cover every finished request
    for fam in ("queue_wait_ms", "ttft_ms", "request_latency_ms"):
        hist = reg.histogram(fam)
        assert hist.count == rep["finished"], fam
    assert reg.histogram("lane_step_ms", lane="cb").count > 0

    # warmup boundary: compiles happened, but all before the rollover
    assert rep["compiles_after_warmup"] == 0
    assert eng.post_warmup_compiles == 0
    assert "warmup" in reg.sections
    assert rep["compiles_total"] > 0

    # the flight recorder saw the full taxonomy on the dense stack
    names = {e.name for e in tel.recorder.events()}
    assert {"compile", "lane_step", "admit", "finish", "d2h",
            "warm_boundary"} <= names

    # second stream on the same engine: the boundary rolls again, so the
    # new report reads only its own stream's counters
    first_cb = rep["lane_calls"]["cb"]
    rep2 = run_continuous_stream(eng, _requests(2), slots=2)
    assert rep2["compiles_after_warmup"] == 0
    assert 0 < rep2["lane_calls"]["cb"] < first_cb + 1
    assert rep2["lane_calls"] == reg.labeled_values(
        "lane_calls_total", "lane"
    )
    eng.close()


def test_disabled_engine_records_zero_events(smoke_setup):
    cfg, params = smoke_setup
    reset_entry_points()
    tel = Telemetry()  # recording disabled (production default)
    eng = Engine(
        cfg, params, EngineConfig(max_len=32, batch_quantum=2, max_batch=2),
        telemetry=tel,
    )
    rep = run_continuous_stream(eng, _requests(3), slots=2)
    assert rep["finished"] == 3
    assert len(tel.recorder) == 0 and tel.recorder.emitted == 0
    # ...but the always-on registry still backed the report
    assert rep["lane_calls"]["cb"] > 0
    eng.close()


def test_burst_report_parity(smoke_setup):
    cfg, params = smoke_setup
    reset_entry_points()
    tel = Telemetry()
    eng = Engine(
        cfg, params, EngineConfig(max_len=16, batch_quantum=2, max_batch=2),
        telemetry=tel,
    )
    rep = run_burst_stream(eng, _requests(2, tokens=3))
    # the burst engine reports through the same registry namespace
    assert rep["lane_calls"] == {"burst": 3}
    assert tel.registry.histogram("lane_step_ms", lane="burst").count == 3
    assert tel.registry.value("mode_switches_total") == rep["mode_switches"]
    eng.close()


def test_compile_report_per_dispatch_key(smoke_setup):
    cfg, params = smoke_setup
    reset_entry_points()
    tel = Telemetry(compile_analysis=True)
    eng = Engine(
        cfg, params, EngineConfig(max_len=16, batch_quantum=2, max_batch=2),
        telemetry=tel,
    )
    eng.continuous(slots=2)
    assert tel.compile_reports
    for rep in tel.compile_reports:
        assert rep["key"] and rep["lane"]
        assert rep["build_ms"] > 0
        assert "error" in rep or (rep["flops"] >= 0 and rep["bytes"] > 0)
    keys = [r["key"] for r in tel.compile_reports]
    assert len(keys) == len(set(keys))  # one report per DispatchKey
    eng.close()


def test_pull_host_emits_d2h_span():
    rec = FlightRecorder(capacity=16, enabled=True)
    out, dt_ns = pull_host(np.arange(6, dtype=np.int32).reshape(2, 3), rec)
    assert out.shape == (2, 3) and dt_ns >= 0
    (ev,) = rec.events()
    assert ev.name == "d2h" and ev.ph == "X" and ev.track == "scheduler"
    assert ev.args["nbytes"] == out.nbytes and ev.args["shape"] == [2, 3]
    # disabled recorder: same result, no events
    out2, _ = pull_host(np.zeros(3), None)
    assert out2.shape == (3,)


def test_page_pool_events():
    from repro.runtime.kvcache import PagePool

    tel = Telemetry(enabled=True)
    pool = PagePool(2, 4, telemetry=tel)
    p0 = pool.alloc()
    p1 = pool.alloc()
    assert pool.alloc() is None  # dry pool
    pool.decref(p0)
    pool.decref(p1)
    names = [e.name for e in tel.recorder.events()]
    assert names.count("page_alloc") == 2
    assert names.count("page_free") == 2
    assert "alloc_failure" in names
    assert "pool_occupancy" in names
    occ = [e for e in tel.recorder.events() if e.name == "pool_occupancy"]
    assert occ[-1].args == {"pages_in_use": 0, "pages_free": 2}


def test_latency_report_registry_only_path():
    # the batcher-less burst path: lane_calls derived straight from a registry
    reg = MetricsRegistry()
    reg.inc("lane_calls_total", 7, lane="burst")
    rep = latency_report([], registry=reg)
    assert rep == {"finished": 0, "lane_calls": {"burst": 7}}
    assert latency_report([]) == {"finished": 0}
